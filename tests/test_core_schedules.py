"""Property + unit tests for the load-balancing abstraction (repro.core)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import (
    Schedule, WorkSpec, blocked_tile_reduce, choose_schedule,
    make_partition, merge_path_partition, tile_reduce, validate_workspec,
)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def spec_from_sizes(sizes):
    sizes = np.asarray(sizes, np.int32)
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int32)
    return WorkSpec.from_segment_offsets(jnp.asarray(offsets),
                                         num_atoms=int(offsets[-1]))


def brute_force_merge_split(tile_offsets, num_atoms, diagonal):
    """Reference merge-path split: simulate the 2-D merge step by step.

    A[t] = tile_offsets[t+1] (tile-end markers), B[j] = j.  Consume the tile
    marker when A[i] <= B[j] (all of the tile's atoms already consumed).
    Returns (tiles_consumed, atoms_consumed) at `diagonal` steps.
    """
    i = j = 0
    T = len(tile_offsets) - 1
    for _ in range(diagonal):
        if i < T and (j >= num_atoms or tile_offsets[i + 1] <= j):
            i += 1
        else:
            j += 1
    return i, j


tile_sizes = st.lists(st.integers(min_value=0, max_value=40), min_size=0,
                      max_size=60)


# ---------------------------------------------------------------------------
# WorkSpec
# ---------------------------------------------------------------------------

class TestWorkSpec:
    def test_from_csr(self):
        spec = WorkSpec.from_csr(jnp.array([0, 2, 2, 5], jnp.int32), nnz=5)
        validate_workspec(spec)
        assert spec.num_tiles == 3 and spec.num_atoms == 5
        np.testing.assert_array_equal(spec.atoms_per_tile(), [2, 0, 3])
        np.testing.assert_array_equal(spec.atom_tile_ids(), [0, 0, 2, 2, 2])

    def test_from_segment_sizes(self):
        spec = WorkSpec.from_segment_sizes(jnp.array([3, 0, 1]), num_atoms=4)
        validate_workspec(spec)
        np.testing.assert_array_equal(spec.tile_offsets, [0, 3, 3, 4])

    @given(tile_sizes)
    @settings(max_examples=50, deadline=None)
    def test_atom_tile_ids_property(self, sizes):
        spec = spec_from_sizes(sizes)
        tids = np.asarray(spec.atom_tile_ids())
        expected = np.repeat(np.arange(len(sizes)), sizes)
        np.testing.assert_array_equal(tids, expected)


# ---------------------------------------------------------------------------
# merge-path partitioner vs brute-force merge
# ---------------------------------------------------------------------------

class TestMergePath:
    @given(tile_sizes, st.integers(min_value=1, max_value=17))
    @settings(max_examples=30, deadline=None)
    def test_matches_bruteforce(self, sizes, num_blocks):
        spec = spec_from_sizes(sizes)
        part = merge_path_partition(spec, num_blocks)
        off = np.asarray(spec.tile_offsets)
        for b in range(num_blocks + 1):
            d = min(b * part.items_per_block, spec.total_work())
            ti, aj = brute_force_merge_split(off, spec.num_atoms, d)
            assert int(part.tile_starts[b]) == ti, (b, d, sizes)
            assert int(part.atom_starts[b]) == aj, (b, d, sizes)

    @given(tile_sizes, st.integers(min_value=1, max_value=17))
    @settings(max_examples=30, deadline=None)
    def test_balance_and_coverage(self, sizes, num_blocks):
        spec = spec_from_sizes(sizes)
        part = merge_path_partition(spec, num_blocks)
        ts = np.asarray(part.tile_starts)
        as_ = np.asarray(part.atom_starts)
        # monotone, full coverage
        assert (np.diff(ts) >= 0).all() and (np.diff(as_) >= 0).all()
        assert ts[0] == 0 and as_[0] == 0
        assert ts[-1] == spec.num_tiles and as_[-1] == spec.num_atoms
        # exact balance: every block gets <= items_per_block work items
        work = np.diff(ts) + np.diff(as_)
        assert (work <= part.items_per_block).all()
        assert work.sum() == spec.total_work()

    def test_pathological_single_heavy_tile(self):
        # One tile owns all atoms: merge-path must still split the atoms.
        spec = spec_from_sizes([0, 0, 10_000, 0])
        part = merge_path_partition(spec, 8)
        atoms = np.diff(np.asarray(part.atom_starts))
        assert atoms.max() <= part.items_per_block
        assert atoms.max() - atoms[atoms > 0].min() <= part.items_per_block


# ---------------------------------------------------------------------------
# all schedules: blocked execution == oracle
# ---------------------------------------------------------------------------

ALL_SCHEDULES = [Schedule.THREAD_MAPPED, Schedule.GROUP_MAPPED,
                 Schedule.WARP_MAPPED, Schedule.BLOCK_MAPPED,
                 Schedule.NONZERO_SPLIT, Schedule.MERGE_PATH]


class TestBlockedExecution:
    @pytest.mark.parametrize("schedule", ALL_SCHEDULES)
    @given(sizes=tile_sizes, num_blocks=st.integers(min_value=1, max_value=9),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_matches_oracle(self, schedule, sizes, num_blocks, seed):
        spec = spec_from_sizes(sizes)
        if spec.num_tiles == 0:
            return
        part = make_partition(spec, schedule, num_blocks)
        rng = np.random.default_rng(seed)
        vals = jnp.asarray(rng.normal(size=max(spec.num_atoms, 1))
                           .astype(np.float32))
        atom_fn = lambda a: vals[jnp.minimum(a, max(spec.num_atoms - 1, 0))]
        got = blocked_tile_reduce(spec, part, atom_fn)
        want = tile_reduce(spec, atom_fn) if spec.num_atoms else jnp.zeros(
            spec.num_tiles)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_partition_invariants_all_schedules(self):
        spec = spec_from_sizes([5, 0, 1, 100, 3, 0, 0, 7])
        for schedule in ALL_SCHEDULES:
            part = make_partition(spec, schedule, 4)
            as_ = np.asarray(part.atom_starts)
            ts = np.asarray(part.tile_starts)
            assert as_[0] == 0 and as_[-1] == spec.num_atoms, schedule
            assert (np.diff(as_) >= 0).all(), schedule
            assert (np.diff(ts) >= 0).all(), schedule
            if part.tile_aligned:
                # atom boundaries coincide with tile boundaries
                off = np.asarray(spec.tile_offsets)
                assert (as_ == off[ts]).all(), schedule


class TestHeuristic:
    def test_paper_heuristic(self):
        # big problems -> merge-path; tiny -> thread/group-mapped (§6.2)
        assert choose_schedule(10**6, 10**8) == Schedule.MERGE_PATH
        assert choose_schedule(100, 150) == Schedule.THREAD_MAPPED
        assert choose_schedule(100, 5000) == Schedule.GROUP_MAPPED
        assert choose_schedule(100, 20_000) == Schedule.MERGE_PATH
        assert choose_schedule(10_000, 500) == Schedule.MERGE_PATH
