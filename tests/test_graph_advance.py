"""Differential suite for the load-balanced graph operators (paper §5.3).

The acceptance bar of the graph subsystem: the frontier-masked ``advance``
must be **bit-identical** to a pure-NumPy oracle under every registered
schedule x execution path, and BFS / SSSP / PageRank built on it must match
scipy-free NumPy references on random and adversarial graphs (isolated
vertices, self-loops, disconnected components, zero-degree tails).  All
machinery comes from the shared conformance library (``_conformance.py``).

Note for CI: the tests with ``native`` in their name are the graph
native-path gate — the tier-1 workflow collects them by keyword and fails
if they disappear.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ExecutionPath, Plan, Schedule,
                        blocked_compact_value_windows, compact_active_atoms,
                        estimate_compact_capacity,
                        estimate_direction_threshold, execute_scatter_reduce,
                        make_partition, modeled_advance_cost,
                        native_compact_value_windows, partition_build_count,
                        score_plans, select_plan, supports_native_execution)
from repro.sparse import (CSR, Graph, advance, advance_frontier,
                          advance_push, advance_relax_min, bfs, bfs_multi,
                          build_advance, delta_stepping, estimate_delta,
                          frontier_filter, pagerank, sssp)
from _conformance import (
    PATHS, SCHEDULES, adversarial_graphs, assert_bitwise_equal,
    check_advance_direction_equivalence, np_advance, np_advance_push,
    np_bfs, np_delta_stepping, np_pagerank, np_sssp, powerlaw_graph_dense,
)

GRAPHS = {"powerlaw": powerlaw_graph_dense(40, avg_degree=5.0, seed=2),
          **adversarial_graphs(seed=3)}


def graph_of(w) -> Graph:
    return Graph(CSR.from_dense(np.asarray(w, np.float32)))


def frontier_of(V, seed, frac=0.4):
    rng = np.random.default_rng(seed)
    f = rng.random(V) < frac
    f[0] = True           # never empty
    return f


class TestAdvanceConformance:
    """advance == NumPy oracle, bit for bit, across the whole matrix."""

    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("path", PATHS, ids=str)
    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_relax_min_matrix(self, name, schedule, path):
        w = GRAPHS[name]
        g = graph_of(w)
        plan = build_advance(g, schedule=schedule, num_blocks=4, path=path)
        assert plan.path == ExecutionPath(path)
        V = g.num_vertices
        rng = np.random.default_rng(7)
        pot = rng.integers(0, 16, V).astype(np.float32)
        frontier = frontier_of(V, seed=8)
        got = advance_relax_min(plan, jnp.asarray(pot), jnp.asarray(frontier))
        pull_off = np.asarray(plan.spec.tile_offsets)
        src = np.asarray(plan.src)
        edge_vals = pot[src] + np.asarray(plan.weight)
        want = np_advance(pull_off, src, edge_vals, frontier, "min")
        assert_bitwise_equal(got, want, f"{name}/{schedule}/{path}")

    @pytest.mark.parametrize("combiner", ["sum", "max"])
    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_sum_and_or_combiners_native_and_pure(self, name, combiner):
        w = GRAPHS[name]
        g = graph_of(w)
        V = g.num_vertices
        frontier = frontier_of(V, seed=9)
        rng = np.random.default_rng(10)
        vertex_vals = rng.integers(1, 9, V).astype(np.float32)
        results = []
        for schedule in SCHEDULES:
            for path in PATHS:
                plan = build_advance(g, schedule=schedule, num_blocks=3,
                                     path=path)
                src = plan.src
                jv = jnp.asarray(vertex_vals)
                got = advance(plan, jnp.asarray(frontier),
                              lambda e: jv[src[e]], combiner=combiner)
                results.append((f"{schedule}/{path}", got, plan))
        pull_off = np.asarray(results[0][2].spec.tile_offsets)
        srcs = np.asarray(results[0][2].src)
        want = np_advance(pull_off, srcs, vertex_vals[srcs], frontier,
                          combiner)
        for label, got, _ in results:
            assert_bitwise_equal(got, want, f"{name}/{label}/{combiner}")

    def test_empty_frontier_yields_identity(self):
        g = graph_of(GRAPHS["powerlaw"])
        V = g.num_vertices
        none = jnp.zeros((V,), bool)
        plan = build_advance(g, schedule="chunked_lpt", num_blocks=4)
        cand = advance_relax_min(plan, jnp.zeros((V,), jnp.float32), none)
        assert bool(jnp.isinf(cand).all())
        assert not bool(advance_frontier(plan, none).any())

    def test_frontier_filter_masks_visited(self):
        # path 0 -> 1 -> 2: filtering out visited vertex 1 empties the
        # next frontier of it, keeps 2 when advancing from {1}
        w = np.zeros((3, 3), np.float32)
        w[0, 1] = w[1, 2] = 1.0
        g = graph_of(w)
        plan = build_advance(g, schedule="merge_path", num_blocks=2)
        frontier = jnp.asarray([True, True, False])
        visited = jnp.asarray([True, True, False])
        nxt = frontier_filter(plan, frontier, keep=~visited)
        np.testing.assert_array_equal(np.asarray(nxt), [False, False, True])


class TestPushDirection:
    """Push advance == pull advance == NumPy oracles, bit for bit.

    These tests carry the ``push``/``direction`` keywords the CI direction
    gate collects by (``-k "push or direction"``); pytest exits 5 if the
    keyword stops matching anything, so silently losing this coverage
    fails the workflow.
    """

    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("path", PATHS, ids=str)
    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_push_relax_min_matrix(self, name, schedule, path):
        w = GRAPHS[name]
        g = graph_of(w)
        plan = build_advance(g, schedule=schedule, num_blocks=4, path=path)
        assert plan.push_path == ExecutionPath(path)
        V = g.num_vertices
        rng = np.random.default_rng(7)
        pot = rng.integers(0, 16, V).astype(np.float32)
        frontier = frontier_of(V, seed=8)
        got = advance_relax_min(plan, jnp.asarray(pot), jnp.asarray(frontier),
                                direction="push")
        psrc = np.asarray(plan.push_src)
        edge_vals = pot[psrc] + np.asarray(plan.push_weight)
        want = np_advance_push(np.asarray(plan.push_spec.tile_offsets),
                               np.asarray(plan.dst), edge_vals, frontier,
                               "min", V)
        assert_bitwise_equal(got, want, f"{name}/{schedule}/{path}")
        pull = advance_relax_min(plan, jnp.asarray(pot),
                                 jnp.asarray(frontier), direction="pull")
        assert_bitwise_equal(got, pull,
                             f"{name}/{schedule}/{path}: directions diverged")

    @pytest.mark.parametrize("combiner", ["sum", "min", "max"])
    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_push_equals_pull_full_matrix(self, name, combiner):
        # the one-call direction-equivalence matrix: every schedule x path
        check_advance_direction_equivalence(GRAPHS[name], combiner=combiner,
                                            seed=11)

    def test_push_empty_frontier_yields_identity(self):
        g = graph_of(GRAPHS["powerlaw"])
        V = g.num_vertices
        none = jnp.zeros((V,), bool)
        plan = build_advance(g, schedule="chunked_lpt", num_blocks=4)
        cand = advance_relax_min(plan, jnp.zeros((V,), jnp.float32), none,
                                 direction="push")
        assert bool(jnp.isinf(cand).all())
        assert not bool(advance_frontier(plan, none,
                                         direction="push").any())

    def test_push_full_frontier_counts_in_degrees(self):
        # exact-once edge coverage through the scatter path
        w = GRAPHS["zero_degree_tail"]
        g = graph_of(w)
        in_deg = (np.asarray(w) > 0).sum(axis=0).astype(np.float32)
        for schedule, path in (("chunked_rr", "native"),
                               ("merge_path", "pure")):
            plan = build_advance(g, schedule=schedule, num_blocks=3,
                                 path=path)
            got = advance_push(plan, jnp.ones((g.num_vertices,), bool),
                               lambda e: jnp.ones(e.shape, jnp.float32),
                               combiner="sum")
            assert_bitwise_equal(got, in_deg, f"{schedule}/{path}")

    def test_plan_pair_is_one_inspector_product(self):
        g = graph_of(GRAPHS["powerlaw"])
        before = partition_build_count()
        plan = build_advance(g, schedule="merge_path", num_blocks=4)
        assert partition_build_count() - before == 2  # one per direction
        assert plan.push_spec.num_atoms == plan.spec.num_atoms == g.num_edges
        assert float(plan.frontier_edge_fraction(
            jnp.ones((g.num_vertices,), bool))) == pytest.approx(1.0)


class TestDirectionOptimizingTraversals:
    """Measured-density direction switching never changes results."""

    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_direction_auto_bfs_matches_pull_only(self, name):
        w = GRAPHS[name]
        g = graph_of(w)
        plan = build_advance(g, schedule="merge_path", num_blocks=4)
        want_depth, want_parent = np_bfs(w, 0)
        for direction in ("auto", "push", "pull"):
            depth, parent = bfs(g, 0, plan=plan, direction=direction,
                                return_parents=True)
            np.testing.assert_array_equal(np.asarray(depth), want_depth,
                                          f"{name}/{direction}")
            np.testing.assert_array_equal(np.asarray(parent), want_parent,
                                          f"{name}/{direction}")

    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_direction_auto_sssp_matches_pull_only(self, name):
        w = GRAPHS[name]
        g = graph_of(w)
        plan = build_advance(g, schedule="chunked_lpt", num_blocks=4)
        pull = sssp(g, 0, plan=plan, direction="pull")
        auto = sssp(g, 0, plan=plan, direction="auto")
        assert_bitwise_equal(auto, pull, name)

    def test_direction_counts_report_the_switch(self):
        # the power-law graph's BFS starts sparse (push) and densifies
        # (pull) — with a mid-range threshold both counters must move
        g = graph_of(GRAPHS["powerlaw"])
        plan = build_advance(g, schedule="merge_path", num_blocks=4,
                             direction_threshold=0.3)
        depth, counts = bfs(g, 0, plan=plan, direction="auto",
                            return_direction_counts=True)
        counts = np.asarray(counts)
        assert counts.sum() > 0
        assert counts[0] > 0, "push never ran"
        assert counts[1] > 0, "pull never ran"
        # forcing the threshold to the extremes pins the direction
        for thr, idx in ((0.0, 0), (1.0, 1)):
            p = build_advance(g, schedule="merge_path", num_blocks=4,
                              direction_threshold=thr)
            _, c = bfs(g, 0, plan=p, direction="auto",
                       return_direction_counts=True)
            assert np.asarray(c)[idx] == 0, (thr, np.asarray(c))

    def test_direction_threshold_is_a_density(self):
        g = graph_of(GRAPHS["powerlaw"])
        plan = build_advance(g, schedule="auto", num_blocks=8)
        assert 0.0 <= plan.direction_threshold <= 1.0
        thr = estimate_direction_threshold(
            plan.spec, plan.push_spec, 8,
            pull_schedule=plan.schedule, push_schedule=plan.push_schedule)
        assert thr == pytest.approx(plan.direction_threshold, abs=1e-6)

    def test_direction_cost_model_crosses_over(self):
        # push must be modeled cheaper at zero density and costlier than
        # pull at full density on an overhead-free pull schedule — the
        # crossover is what direction optimization exists for
        g = graph_of(powerlaw_graph_dense(120, avg_degree=8.0, seed=4))
        pull_spec = g.csr.transpose().workspec()
        push_spec = g.csr.workspec()
        lo_push = modeled_advance_cost(push_spec, "merge_path", 8,
                                       direction="push", density=0.0)
        lo_pull = modeled_advance_cost(pull_spec, "merge_path", 8,
                                       direction="pull", density=0.0)
        hi_push = modeled_advance_cost(push_spec, "merge_path", 8,
                                       direction="push", density=1.0)
        hi_pull = modeled_advance_cost(pull_spec, "merge_path", 8,
                                       direction="pull", density=1.0)
        assert lo_push < lo_pull
        assert hi_push > hi_pull
        with pytest.raises(ValueError):
            modeled_advance_cost(pull_spec, "merge_path", 8,
                                 direction="sideways")

    def test_direction_multi_source_bfs_shares_the_plan_pair(self):
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        plan = build_advance(g, schedule="adaptive", num_blocks=4)
        sources = [0, 3, 9]
        before = partition_build_count()
        batched = np.asarray(bfs_multi(g, sources, plan=plan))
        assert partition_build_count() == before  # no re-inspection
        for i, s in enumerate(sources):
            want, _ = np_bfs(w, s)
            np.testing.assert_array_equal(batched[i], want, f"source {s}")


class TestTraversalsVsReferences:
    """BFS/SSSP/PageRank drivers vs scipy-free NumPy references."""

    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_bfs_depth_and_parents(self, name):
        w = GRAPHS[name]
        g = graph_of(w)
        depth, parent = bfs(g, 0, schedule="merge_path", num_blocks=4,
                            return_parents=True)
        want_depth, want_parent = np_bfs(w, 0)
        np.testing.assert_array_equal(np.asarray(depth), want_depth, name)
        np.testing.assert_array_equal(np.asarray(parent), want_parent, name)

    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_sssp_distances(self, name):
        w = GRAPHS[name]
        g = graph_of(w)
        dist = np.asarray(sssp(g, 0, schedule="chunked_lpt", num_blocks=4))
        np.testing.assert_allclose(dist, np_sssp(w, 0), rtol=1e-6,
                                   err_msg=name)

    @pytest.mark.parametrize("name", ["powerlaw", "disconnected",
                                      "star_hub"])
    def test_pagerank(self, name):
        w = GRAPHS[name]
        g = graph_of(w)
        pr = np.asarray(pagerank(g, num_iters=40, schedule="adaptive",
                                 num_blocks=4))
        np.testing.assert_allclose(pr, np_pagerank(w, num_iters=40),
                                   rtol=1e-4, atol=1e-7, err_msg=name)
        np.testing.assert_allclose(pr.sum(), 1.0, rtol=1e-4)

    def test_bfs_native_schedule_sweep_bit_identical(self):
        # the graph native-path gate: every schedule on the native kernel
        # must reproduce the pure path's BFS labels exactly
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        want, _ = np_bfs(w, 0)
        for schedule in SCHEDULES:
            for path in PATHS:
                depth = bfs(g, 0, schedule=schedule, num_blocks=4, path=path)
                np.testing.assert_array_equal(
                    np.asarray(depth), want, f"{schedule}/{path}")

    def test_sssp_native_matches_pure_bitwise(self):
        w = GRAPHS["zero_degree_tail"]
        g = graph_of(w)
        native = sssp(g, 0, schedule="chunked_rr", num_blocks=4,
                      path="native")
        pure = sssp(g, 0, schedule="chunked_rr", num_blocks=4, path="pure")
        assert_bitwise_equal(native, pure)


class TestAdvanceAutotune:
    """schedule="auto" selects a plan for advance workloads (acceptance)."""

    def test_auto_plan_is_advance_argmin(self):
        g = graph_of(powerlaw_graph_dense(120, avg_degree=8.0, skew=1.5,
                                          seed=4))
        spec = g.csr.transpose().workspec()
        plan = select_plan(spec, 16, cache=None, workload="advance")
        scores = score_plans(spec, 16, workload="advance")
        assert scores[plan] == min(scores.values())

    def test_build_advance_auto_runs_and_matches(self):
        w = powerlaw_graph_dense(60, avg_degree=6.0, seed=5)
        g = graph_of(w)
        plan = build_advance(g, schedule="auto", num_blocks=8)
        assert plan.schedule in set(SCHEDULES)
        assert supports_native_execution(plan.part)
        depth = bfs(g, 0, plan=plan)
        want, _ = np_bfs(w, 0)
        np.testing.assert_array_equal(np.asarray(depth), want)

    def test_advance_workload_changes_cost_ordering_inputs(self):
        # the advance family scores atoms heavier than the reduce family;
        # per-block overheads are unscaled, so relative scores must differ
        g = graph_of(powerlaw_graph_dense(80, avg_degree=6.0, seed=6))
        spec = g.csr.transpose().workspec()
        reduce_scores = score_plans(spec, 8, workload="reduce")
        advance_scores = score_plans(spec, 8, workload="advance")
        assert any(advance_scores[p] > reduce_scores[p]
                   for p in reduce_scores)

    def test_advance_cache_namespace_is_disjoint(self, tmp_path):
        from repro.core import AutotuneCache
        cache = AutotuneCache(tmp_path / "cache.json")
        g = graph_of(powerlaw_graph_dense(50, avg_degree=5.0, seed=7))
        spec = g.csr.transpose().workspec()
        select_plan(spec, 8, cache=cache, workload="reduce")
        select_plan(spec, 8, cache=cache, workload="advance")
        keys = set(cache._mem)
        assert any(k.endswith("|plan") for k in keys)
        assert any(k.endswith("|plan.advance") for k in keys)

    def test_push_workload_family_selects_and_namespaces(self, tmp_path):
        from repro.core import AutotuneCache
        cache = AutotuneCache(tmp_path / "cache.json")
        g = graph_of(powerlaw_graph_dense(120, avg_degree=8.0, skew=1.5,
                                          seed=4))
        push_spec = g.csr.workspec()
        plan = select_plan(push_spec, 16, cache=cache,
                           workload="advance_push")
        scores = score_plans(push_spec, 16, workload="advance_push")
        assert scores[plan] == min(scores.values())
        assert any(k.endswith("|plan.advance_push") for k in cache._mem)
        # the push family charges active atoms heavier than the pull family
        adv = score_plans(push_spec, 16, workload="advance")
        assert any(scores[p] > adv[p] for p in adv)

    def test_build_advance_auto_selects_push_plan_jointly(self):
        g = graph_of(powerlaw_graph_dense(60, avg_degree=6.0, seed=5))
        plan = build_advance(g, schedule="auto", num_blocks=8)
        assert plan.push_schedule in set(SCHEDULES)
        assert supports_native_execution(plan.push_part)
        # direction equivalence survives independently chosen schedules
        depth_auto = bfs(g, 0, plan=plan, direction="auto")
        depth_pull = bfs(g, 0, plan=plan, direction="pull")
        np.testing.assert_array_equal(np.asarray(depth_auto),
                                      np.asarray(depth_pull))

    def test_unknown_workload_rejected(self):
        g = graph_of(GRAPHS["self_loops"])
        spec = g.csr.transpose().workspec()
        with pytest.raises(ValueError):
            select_plan(spec, 4, cache=None, workload="scan")


class TestDeltaStepping:
    """Delta-stepping SSSP == frontier Bellman-Ford, bit for bit.

    These tests carry the ``delta`` keyword the CI bucketed-traversal gate
    collects (``-k "delta or compact"``); pytest exits 5 if the keyword
    stops matching anything, so silently losing this coverage fails the
    workflow.
    """

    @pytest.mark.parametrize("name", sorted(GRAPHS))
    def test_delta_matches_bellman_ford_full_matrix(self, name):
        # the acceptance matrix: all 6 schedules x both execution paths x
        # both directions, one BF reference per graph (BF itself is
        # schedule/path-invariant — asserted by the PR-3/4 suites)
        w = GRAPHS[name]
        g = graph_of(w)
        want = np.asarray(sssp(g, 0, schedule="merge_path", num_blocks=4))
        for schedule in SCHEDULES:
            for path in PATHS:
                plan = build_advance(g, schedule=schedule, num_blocks=4,
                                     path=path, delta="auto", compact=True)
                for direction in ("pull", "push"):
                    got = delta_stepping(g, 0, plan=plan,
                                         direction=direction)
                    assert_bitwise_equal(
                        got, want, f"{name}/{schedule}/{path}/{direction}")

    @pytest.mark.parametrize("delta", [0.5, 1.0, 3.0, 64.0])
    def test_delta_width_never_changes_bits(self, delta):
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        plan = build_advance(g, schedule="chunked_lpt", num_blocks=4)
        want = np.asarray(sssp(g, 0, plan=plan))
        got = np.asarray(delta_stepping(g, 0, plan=plan, delta=delta))
        assert_bitwise_equal(got, want, f"delta={delta}")
        assert_bitwise_equal(got, np_delta_stepping(w, 0, delta),
                             f"np oracle, delta={delta}")

    @pytest.mark.parametrize("name", ["powerlaw", "star_hub",
                                      "zero_degree_tail"])
    def test_delta_numpy_oracle_bitwise(self, name):
        w = GRAPHS[name]
        g = graph_of(w)
        got = np.asarray(delta_stepping(g, 0, schedule="merge_path",
                                        num_blocks=4))
        assert_bitwise_equal(got, np_delta_stepping(w, 0), name)
        np.testing.assert_allclose(np.asarray(got), np_sssp(w, 0),
                                   rtol=1e-6, err_msg=name)

    def test_delta_exhausted_cap_still_converges(self):
        # a deliberately starved outer cap must not truncate: the
        # Bellman-Ford backstop finishes the leftover relaxations, so
        # bit-identity holds unconditionally (a bad cap costs rounds,
        # never bits)
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        plan = build_advance(g, schedule="merge_path", num_blocks=4,
                             delta=0.5)      # many buckets
        want = np.asarray(sssp(g, 0, plan=plan))
        for cap in (0, 1, 2):
            got = np.asarray(delta_stepping(g, 0, plan=plan,
                                            max_iters=cap))
            assert_bitwise_equal(got, want, f"max_iters={cap}")

    def test_sssp_algorithm_param_routes_to_delta(self):
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        bf = sssp(g, 0, schedule="merge_path", num_blocks=4)
        ds = sssp(g, 0, schedule="merge_path", num_blocks=4,
                  algorithm="delta", delta=2.0)
        assert_bitwise_equal(ds, bf)
        with pytest.raises(ValueError):
            sssp(g, 0, algorithm="dijkstra")

    def test_delta_split_partitions_the_edge_set(self):
        g = graph_of(GRAPHS["powerlaw"])
        plan = build_advance(g, schedule="merge_path", num_blocks=4,
                             delta="auto")
        assert plan.delta is not None and plan.delta > 0
        E = g.num_edges
        light = np.asarray(plan.light_mask)
        push_light = np.asarray(plan.push_light_mask)
        assert light.shape == (E,) and push_light.shape == (E,)
        # same multiset of weights on both sides: the split is per-edge,
        # order differs per direction
        assert light.sum() == push_light.sum()
        assert np.all(np.asarray(plan.push_weight)[push_light] <= plan.delta)
        assert np.all(np.asarray(plan.push_weight)[~push_light] > plan.delta)
        # the measured light density term sums the push-side split
        assert int(np.asarray(plan.light_out_degrees).sum()) == \
            int(push_light.sum())

    def test_delta_default_width_is_the_mean_weight(self):
        g = graph_of(GRAPHS["powerlaw"])
        plan = build_advance(g, schedule="merge_path", num_blocks=4,
                             delta="auto")
        w = np.asarray(plan.push_weight)
        assert plan.delta == pytest.approx(
            max(np.float32(w.mean()), w.min()))
        assert estimate_delta(w) == plan.delta
        assert estimate_delta(np.zeros((0,), np.float32)) == 1.0

    def test_delta_requires_positive_width(self):
        g = graph_of(GRAPHS["self_loops"])
        plan = build_advance(g, schedule="merge_path", num_blocks=2)
        with pytest.raises(ValueError):
            plan.with_delta(0.0)
        with pytest.raises(ValueError):
            plan.with_delta(-1.0)

    def test_delta_edges_selector_needs_a_split(self):
        g = graph_of(GRAPHS["self_loops"])
        plan = build_advance(g, schedule="merge_path", num_blocks=2)
        pot = jnp.zeros((g.num_vertices,), jnp.float32)
        frontier = jnp.ones((g.num_vertices,), bool)
        with pytest.raises(ValueError):
            advance_relax_min(plan, pot, frontier, edges="light")
        with pytest.raises(ValueError):
            advance_relax_min(plan, pot, frontier, edges="sideways")

    def test_delta_light_heavy_advances_cover_exactly_once(self):
        # light + heavy unit sum-advances == the full advance: the split is
        # a partition of the edge set, no edge dropped or double-counted
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        plan = build_advance(g, schedule="chunked_lpt", num_blocks=4,
                             delta="auto")
        frontier = jnp.ones((g.num_vertices,), bool)
        unit = lambda e: jnp.ones(e.shape, jnp.float32)
        in_deg = (np.asarray(w) > 0).sum(axis=0).astype(np.float32)
        for direction, adv in (("pull", advance), ("push", advance_push)):
            light = adv(plan, frontier, unit, combiner="sum",
                        edge_mask=plan.edge_set_mask("light", direction))
            heavy = adv(plan, frontier, unit, combiner="sum",
                        edge_mask=plan.edge_set_mask("heavy", direction))
            assert_bitwise_equal(np.asarray(light) + np.asarray(heavy),
                                 in_deg, direction)

    def test_delta_direction_counts_report_the_switch(self):
        g = graph_of(GRAPHS["powerlaw"])
        plan = build_advance(g, schedule="merge_path", num_blocks=4,
                             delta="auto", direction_threshold=0.3)
        dist, counts = delta_stepping(g, 0, plan=plan, direction="auto",
                                      return_direction_counts=True)
        counts = np.asarray(counts)
        assert counts.sum() > 0
        # pinning the threshold pins every bucket phase's direction
        for thr, idx in ((0.0, 0), (1.0, 1)):
            p = build_advance(g, schedule="merge_path", num_blocks=4,
                              delta="auto", direction_threshold=thr)
            _, c = delta_stepping(g, 0, plan=p, direction="auto",
                                  return_direction_counts=True)
            assert np.asarray(c)[idx] == 0, (thr, np.asarray(c))

    def test_delta_autotune_family_selects_and_namespaces(self, tmp_path):
        from repro.core import AutotuneCache
        cache = AutotuneCache(tmp_path / "cache.json")
        g = graph_of(powerlaw_graph_dense(120, avg_degree=8.0, skew=1.5,
                                          seed=4))
        spec = g.csr.transpose().workspec()
        plan = select_plan(spec, 16, cache=cache, workload="advance_delta")
        scores = score_plans(spec, 16, workload="advance_delta")
        assert scores[plan] == min(scores.values())
        assert any(k.endswith("|plan.advance_delta") for k in cache._mem)
        # bucketed advances charge atoms heavier than the plain family
        adv = score_plans(spec, 16, workload="advance")
        assert any(scores[p] > adv[p] for p in adv)
        push_spec = g.csr.workspec()
        select_plan(push_spec, 16, cache=cache,
                    workload="advance_delta_push")
        assert any(k.endswith("|plan.advance_delta_push")
                   for k in cache._mem)

    def test_delta_auto_schedule_builds_and_matches(self):
        w = powerlaw_graph_dense(60, avg_degree=6.0, seed=5)
        g = graph_of(w)
        dist = np.asarray(sssp(g, 0, schedule="auto", num_blocks=8,
                               algorithm="delta"))
        np.testing.assert_allclose(dist, np_sssp(w, 0), rtol=1e-6)


class TestCompactWindows:
    """Gather-compacted push windows == masked full windows, bit for bit.

    The ``compact`` keyword half of the CI bucketed-traversal gate
    (``-k "delta or compact"``).
    """

    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("path", PATHS, ids=str)
    def test_compact_scatter_reduce_matches_masked(self, schedule, path):
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        V = g.num_vertices
        spec = g.csr.workspec()
        part = make_partition(spec, schedule, 4)
        rng = np.random.default_rng(21)
        vals = jnp.asarray(rng.integers(-8, 9, spec.num_atoms)
                           .astype(np.float32))
        atom_fn = lambda e: vals[e]
        mask = jnp.asarray(rng.random(spec.num_atoms) < 0.3)
        for combiner in ("sum", "min", "max"):
            want = execute_scatter_reduce(
                spec, part, atom_fn, g.csr.col_indices, V, path=path,
                combiner=combiner, atom_mask=mask)
            for capacity in (spec.num_atoms, int(mask.sum()) + 3):
                got = execute_scatter_reduce(
                    spec, part, atom_fn, g.csr.col_indices, V, path=path,
                    combiner=combiner, atom_mask=mask,
                    compact_capacity=capacity)
                assert_bitwise_equal(
                    got, want, f"{schedule}/{path}/{combiner}/{capacity}")

    def test_compact_overflow_falls_back_to_masked(self):
        # a capacity smaller than the active count must not drop atoms —
        # the executor's lax.cond falls back to masked full windows
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        spec = g.csr.workspec()
        part = make_partition(spec, Schedule.CHUNKED, 4)
        vals = jnp.ones((spec.num_atoms,), jnp.float32)
        mask = jnp.ones((spec.num_atoms,), bool)      # everything active
        for path in PATHS:
            got = execute_scatter_reduce(
                spec, part, lambda e: vals[e], g.csr.col_indices,
                g.num_vertices, path=path, combiner="sum", atom_mask=mask,
                compact_capacity=4)
            in_deg = (np.asarray(w) > 0).sum(axis=0).astype(np.float32)
            assert_bitwise_equal(got, in_deg, str(path))

    def test_compact_windows_native_equals_pure(self):
        w = GRAPHS["zero_degree_tail"]
        g = graph_of(w)
        spec = g.csr.workspec()
        part = make_partition(spec, Schedule.CHUNKED, 3,
                              chunk_policy="round_robin")
        rng = np.random.default_rng(5)
        vals = jnp.asarray(rng.integers(-8, 9, spec.num_atoms)
                           .astype(np.float32))
        mask = jnp.asarray(rng.random(spec.num_atoms) < 0.5)
        idx, count = compact_active_atoms(mask, spec.num_atoms)
        assert int(count) == int(np.asarray(mask).sum())
        pure = blocked_compact_value_windows(spec, part, lambda e: vals[e],
                                             idx)
        native = native_compact_value_windows(spec, part, lambda e: vals[e],
                                              idx)
        assert pure.shape == native.shape
        assert_bitwise_equal(pure.reshape(-1), native.reshape(-1))

    def test_compact_advance_push_rides_the_plan(self):
        # a plan built with compact= must keep push advances bit-identical
        # to an uncompacted plan on sparse AND saturating frontiers
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        V = g.num_vertices
        plain = build_advance(g, schedule="merge_path", num_blocks=4)
        compact = build_advance(g, schedule="merge_path", num_blocks=4,
                                compact=0.25)
        assert compact.compact_capacity == int(np.ceil(g.num_edges * 0.25))
        rng = np.random.default_rng(9)
        pot = jnp.asarray(rng.integers(0, 16, V).astype(np.float32))
        for frac in (0.1, 0.9):
            frontier = jnp.asarray(rng.random(V) < frac)
            want = advance_relax_min(plain, pot, frontier, direction="push")
            got = advance_relax_min(compact, pot, frontier,
                                    direction="push")
            assert_bitwise_equal(got, want, f"frontier {frac}")

    def test_compact_rejects_degenerate_requests(self):
        g = graph_of(GRAPHS["self_loops"])
        for bad in (0, -5):
            with pytest.raises(ValueError, match="compact capacity"):
                build_advance(g, schedule="merge_path", num_blocks=2,
                              compact=bad)
        with pytest.raises(ValueError, match="compact fraction"):
            build_advance(g, schedule="merge_path", num_blocks=2,
                          compact=1.5)
        # None/False both mean disabled, not capacity-1
        for off in (None, False):
            plan = build_advance(g, schedule="merge_path", num_blocks=2,
                                 compact=off)
            assert plan.compact_capacity is None

    def test_compact_capacity_estimate_tracks_threshold(self):
        assert estimate_compact_capacity(1000, 0.25) == \
            int(np.ceil(1000 * 0.25 * 1.25))
        assert estimate_compact_capacity(1000, 0.0) == 32      # floor
        assert estimate_compact_capacity(1000, 1.0) == 1000    # clamp to E
        assert estimate_compact_capacity(0, 0.5) == 1
        g = graph_of(GRAPHS["powerlaw"])
        plan = build_advance(g, schedule="merge_path", num_blocks=4,
                             compact=True)
        assert plan.compact_capacity == estimate_compact_capacity(
            g.num_edges, plan.direction_threshold)

    def test_compact_cost_model_flattens_skew(self):
        # a hub-skewed push view: the compacted even split must be modeled
        # cheaper than masked thread-mapped windows (which pay the hub),
        # and the mode must reject pull (nothing to compact)
        g = graph_of(GRAPHS["star_hub"])
        push_spec = g.csr.workspec()
        masked = modeled_advance_cost(push_spec, "thread_mapped", 4,
                                      direction="push", density=0.3)
        compacted = modeled_advance_cost(push_spec, "thread_mapped", 4,
                                         direction="push", density=0.3,
                                         window_mode="compact")
        assert compacted < masked
        with pytest.raises(ValueError):
            modeled_advance_cost(push_spec, "thread_mapped", 4,
                                 direction="pull", window_mode="compact")
        with pytest.raises(ValueError):
            modeled_advance_cost(push_spec, "thread_mapped", 4,
                                 direction="push", window_mode="wide")

    def test_compact_delta_stepping_end_to_end(self):
        # the tentpole composition: bucketed traversal + compacted windows
        w = GRAPHS["powerlaw"]
        g = graph_of(w)
        want = np.asarray(sssp(g, 0, schedule="merge_path", num_blocks=4))
        for compact in (True, 0.5, 16, None):
            got = np.asarray(delta_stepping(g, 0, schedule="merge_path",
                                            num_blocks=4, compact=compact,
                                            direction="push"))
            assert_bitwise_equal(got, want, f"compact={compact}")


class TestSourceValidation:
    """Out-of-range sources raise at build time instead of clamping."""

    @pytest.mark.parametrize("source", [-1, 40, 1000])
    def test_bad_source_raises(self, source):
        g = graph_of(GRAPHS["powerlaw"])     # V = 40
        plan = build_advance(g, schedule="merge_path", num_blocks=4)
        for fn in (lambda: bfs(g, source, plan=plan),
                   lambda: sssp(g, source, plan=plan),
                   lambda: delta_stepping(g, source, plan=plan),
                   lambda: sssp(g, source, plan=plan, algorithm="delta")):
            with pytest.raises(ValueError, match="out of range"):
                fn()

    def test_bfs_multi_bad_batch_entry_raises(self):
        g = graph_of(GRAPHS["powerlaw"])     # V = 40
        plan = build_advance(g, schedule="merge_path", num_blocks=4)
        for sources in ([0, -1, 3], [0, 40], [-1], [0, 1, 1000]):
            with pytest.raises(ValueError, match="out of range"):
                bfs_multi(g, sources, plan=plan)
        # the all-valid batch still runs
        assert np.asarray(bfs_multi(g, [0, 39], plan=plan)).shape == (2, 40)

    def test_boundary_sources_are_valid(self):
        w = GRAPHS["self_loops"]             # V = 8
        g = graph_of(w)
        plan = build_advance(g, schedule="merge_path", num_blocks=2)
        for source in (0, 7):
            want, _ = np_bfs(w, source)
            np.testing.assert_array_equal(
                np.asarray(bfs(g, source, plan=plan)), want)


class TestEmptyGraphs:
    """V == 0 and E == 0 graphs must not crash (satellite of PR 5)."""

    @pytest.mark.parametrize("schedule", SCHEDULES)
    @pytest.mark.parametrize("path", PATHS, ids=str)
    def test_edgeless_graph_traversals(self, schedule, path):
        V = 7
        g = graph_of(np.zeros((V, V), np.float32))
        plan = build_advance(g, schedule=schedule, num_blocks=4, path=path,
                             delta="auto", compact=True)
        assert plan.num_edges == 0 and plan.delta == 1.0
        depth = np.asarray(bfs(g, 2, plan=plan))
        want_depth = np.full(V, -1); want_depth[2] = 0
        np.testing.assert_array_equal(depth, want_depth)
        dist = np.asarray(sssp(g, 2, plan=plan))
        want_dist = np.full(V, np.inf, np.float32); want_dist[2] = 0.0
        assert_bitwise_equal(dist, want_dist)
        assert_bitwise_equal(delta_stepping(g, 2, plan=plan), want_dist)
        batched = np.asarray(bfs_multi(g, [0, 6], plan=plan))
        assert batched.shape == (2, V)
        assert (batched >= 0).sum() == 2     # each source reaches itself

    def test_vertexless_graph(self):
        g = graph_of(np.zeros((0, 0), np.float32))
        assert g.num_vertices == 0 and g.num_edges == 0
        # build_advance handles the empty CSR in every direction
        plan = build_advance(g, schedule="merge_path", num_blocks=4,
                             delta="auto")
        assert plan.num_edges == 0
        # there is no valid source: the validators reject every candidate
        for fn in (lambda: bfs(g, 0, plan=plan),
                   lambda: sssp(g, 0, plan=plan),
                   lambda: delta_stepping(g, 0, plan=plan)):
            with pytest.raises(ValueError):
                fn()
        # source-free entry points return empty results, like pagerank
        assert np.asarray(bfs_multi(g, [], plan=plan)).shape == (0, 0)
        assert np.asarray(pagerank(g)).shape == (0,)

    def test_edgeless_pagerank_is_uniform(self):
        V = 5
        g = graph_of(np.zeros((V, V), np.float32))
        pr = np.asarray(pagerank(g, num_iters=10))
        np.testing.assert_allclose(pr, np.full(V, 1.0 / V), rtol=1e-6)


class TestSsspDirectionCounts:
    """sssp reports (push, pull) iteration counts like bfs (parity fix)."""

    def test_sssp_direction_counts_report_the_switch(self):
        g = graph_of(GRAPHS["powerlaw"])
        plan = build_advance(g, schedule="merge_path", num_blocks=4,
                             direction_threshold=0.3)
        dist, counts = sssp(g, 0, plan=plan, direction="auto",
                            return_direction_counts=True)
        counts = np.asarray(counts)
        assert counts.sum() > 0
        assert counts[0] > 0, "push never ran"
        assert counts[1] > 0, "pull never ran"
        assert_bitwise_equal(dist, sssp(g, 0, plan=plan, direction="pull"))
        # forcing the threshold to the extremes pins the direction
        for thr, idx in ((0.0, 0), (1.0, 1)):
            p = build_advance(g, schedule="merge_path", num_blocks=4,
                              direction_threshold=thr)
            _, c = sssp(g, 0, plan=p, direction="auto",
                        return_direction_counts=True)
            assert np.asarray(c)[idx] == 0, (thr, np.asarray(c))

    def test_sssp_fixed_direction_counts_are_pinned(self):
        g = graph_of(GRAPHS["self_loops"])
        plan = build_advance(g, schedule="merge_path", num_blocks=2)
        _, c_push = sssp(g, 0, plan=plan, direction="push",
                         return_direction_counts=True)
        _, c_pull = sssp(g, 0, plan=plan, direction="pull",
                         return_direction_counts=True)
        assert np.asarray(c_push)[1] == 0 and np.asarray(c_push)[0] > 0
        assert np.asarray(c_pull)[0] == 0 and np.asarray(c_pull)[1] > 0
