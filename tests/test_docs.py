"""Docs gate (tools/check_docs.py): link integrity + runnable blocks.

Tier-1 mirrors what CI's ``docs`` job blocks on: every relative markdown
link in the repo resolves (file + heading anchor), and the ``python run``
blocks in docs/autotune.md actually execute.  The doc's walkthroughs are
the autotuning story's executable spec — if the API drifts, this fails
before the prose goes stale.
"""
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


class TestLinkCheck:
    def test_repo_markdown_links_resolve(self):
        errors = check_docs.check_links(list(check_docs._markdown_files()))
        assert errors == []

    def test_broken_link_detected(self, tmp_path, monkeypatch):
        bad = tmp_path / "bad.md"
        bad.write_text("see [missing](no_such_file.md)\n")
        errors = check_docs.check_links([bad])
        assert len(errors) == 1 and "broken link" in errors[0]

    def test_missing_anchor_detected(self, tmp_path):
        dest = tmp_path / "dest.md"
        dest.write_text("# Real Heading\n")
        src = tmp_path / "src.md"
        src.write_text("[ok](dest.md#real-heading) [bad](dest.md#nope)\n")
        errors = check_docs.check_links([src])
        assert len(errors) == 1 and "missing anchor" in errors[0]

    def test_external_links_skipped(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("[a](https://example.com/x) [b](mailto:x@y.z)\n")
        assert check_docs.check_links([md]) == []

    def test_fenced_code_not_scanned(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("```json\n[\"key\"](not_a_link.md)\n```\n")
        assert check_docs.check_links([md]) == []


class TestSlugify:
    @pytest.mark.parametrize("heading,slug", [
        ("The lifecycle: model → measure → blend → fit",
         "the-lifecycle-model--measure--blend--fit"),
        ("Cache format: v1 strings and v2 measured records",
         "cache-format-v1-strings-and-v2-measured-records"),
        ("`code` and *emphasis*", "code-and-emphasis"),
    ])
    def test_github_style(self, heading, slug):
        assert check_docs._slugify(heading) == slug


class TestRunnableBlocks:
    def test_extraction(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("```python\nillustrative = True\n```\n"
                      "```python run\nx = 1\n```\n"
                      "```python run\ny = x + 1\n```\n")
        blocks = list(check_docs._runnable_blocks(md))
        assert blocks == ["x = 1", "y = x + 1"]

    def test_unterminated_block_is_error(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("```python run\nx = 1\n")
        with pytest.raises(SyntaxError, match="unterminated"):
            list(check_docs._runnable_blocks(md))

    def test_blocks_share_one_namespace(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("```python run\nx = 2\n```\n"
                      "```python run\nassert x == 2\n```\n")
        assert check_docs.run_doctests([md]) == []

    def test_failing_block_reported(self, tmp_path):
        md = tmp_path / "x.md"
        md.write_text("```python run\nraise RuntimeError('boom')\n```\n")
        errors = check_docs.run_doctests([md])
        assert len(errors) == 1 and "boom" in errors[0]


class TestAutotuneDocExecutes:
    def test_autotune_doc_blocks_run(self):
        """The committed walkthroughs execute against the live API."""
        md = REPO / "docs" / "autotune.md"
        assert list(check_docs._runnable_blocks(md)), "doc lost its blocks"
        assert check_docs.run_doctests([md]) == []

    def test_cli_entrypoint_links_only(self):
        out = subprocess.run(
            [sys.executable, str(REPO / "tools" / "check_docs.py"),
             "--links-only"],
            capture_output=True, text=True, cwd=REPO)
        assert out.returncode == 0, out.stderr
        assert "CHECK_DOCS_OK" in out.stdout
