#!/usr/bin/env python
"""Docs gate: markdown cross-link integrity + doctest of runnable blocks.

Two checks, both blocking in CI (the ``docs`` job):

1. **Link check** — every relative markdown link in the repo's ``*.md``
   files must resolve to an existing file, and a ``#fragment`` must match
   a heading anchor (GitHub slugification) in the target.  External
   (``http(s)://``, ``mailto:``) links are skipped — CI must not depend
   on the network.

2. **Doctest** — fenced code blocks opened with \`\`\`python run are
   executed top-to-bottom, each file in one fresh namespace (blocks in a
   file may build on earlier blocks).  A raised exception fails the
   check.  Plain \`\`\`python blocks are illustrative and never run.

Usage::

    PYTHONPATH=src python tools/check_docs.py            # links + doctests
    PYTHONPATH=src python tools/check_docs.py --links-only
    PYTHONPATH=src python tools/check_docs.py docs/autotune.md README.md
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# directories never scanned for markdown
SKIP_DIRS = {".git", ".venv", "node_modules", "__pycache__", ".pytest_cache"}

def _rel(md: pathlib.Path) -> str:
    try:
        return str(md.relative_to(REPO))
    except ValueError:          # files outside the repo (tests use tmpdirs)
        return str(md)


LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
FENCE_RE = re.compile(r"^```")


def _markdown_files():
    for p in sorted(REPO.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


def _slugify(heading: str) -> str:
    """GitHub-style heading anchor: lowercase, drop punctuation, dash."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def _anchors(md: pathlib.Path) -> set:
    out, fenced = set(), False
    for line in md.read_text().splitlines():
        if FENCE_RE.match(line):
            fenced = not fenced
            continue
        if fenced:
            continue
        m = HEADING_RE.match(line)
        if m:
            out.add(_slugify(m.group(1)))
    return out


def _links(md: pathlib.Path):
    """Yield link targets, skipping fenced code (sample JSON, shell)."""
    fenced = False
    for line in md.read_text().splitlines():
        if FENCE_RE.match(line):
            fenced = not fenced
            continue
        if fenced:
            continue
        for m in LINK_RE.finditer(line):
            yield m.group(1)


def check_links(files) -> list:
    errors = []
    for md in files:
        for target in _links(md):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # scheme: external
                continue
            path_part, _, frag = target.partition("#")
            dest = md if not path_part else (
                md.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{_rel(md)}: broken link "
                              f"-> {target}")
                continue
            if frag and dest.suffix == ".md":
                if _slugify(frag) not in _anchors(dest):
                    errors.append(f"{_rel(md)}: missing anchor "
                                  f"-> {target}")
    return errors


def _runnable_blocks(md: pathlib.Path):
    block, collecting = [], False
    for line in md.read_text().splitlines():
        if collecting:
            if line.startswith("```"):
                yield "\n".join(block)
                block, collecting = [], False
            else:
                block.append(line)
        elif line.strip() == "```python run":
            collecting = True
    if collecting:
        raise SyntaxError(f"{md}: unterminated ```python run block")


def run_doctests(files) -> list:
    errors = []
    for md in files:
        blocks = list(_runnable_blocks(md))
        if not blocks:
            continue
        ns = {"__name__": f"doctest_{md.stem}"}
        for i, src in enumerate(blocks, 1):
            try:
                exec(compile(src, f"{_rel(md)}[block {i}]",
                             "exec"), ns)
            except Exception as e:                    # noqa: BLE001
                errors.append(f"{_rel(md)} block {i}: "
                              f"{type(e).__name__}: {e}")
                break                                 # later blocks may chain
        print(f"doctest {_rel(md)}: {len(blocks)} block(s)")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    help="markdown files to check (default: all tracked)")
    ap.add_argument("--links-only", action="store_true",
                    help="skip executing runnable blocks")
    args = ap.parse_args(argv)

    files = ([(REPO / f).resolve() for f in args.files]
             if args.files else list(_markdown_files()))
    for f in files:
        if not f.exists():
            print(f"CHECK-DOCS FAIL: no such file: {f}", file=sys.stderr)
            return 2

    errors = check_links(files)
    print(f"link check: {len(files)} file(s), {len(errors)} error(s)")
    if not args.links_only:
        errors += run_doctests(files)

    for e in errors:
        print(f"CHECK-DOCS FAIL: {e}", file=sys.stderr)
    if errors:
        return 1
    print("CHECK_DOCS_OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
